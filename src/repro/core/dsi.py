"""Disparity Space Image (DSI): the ray-density volume.

Layout: (Nz, h, w), z-major so one depth plane is a contiguous (h, w)
image — matching both the FPGA's per-PE_Zi plane buffers and the Pallas
kernel's per-grid-step VMEM tile.

Scores are int32 while accumulating (overflow-safe), stored/checkpointed
as int16 per the paper's DSI-score quantization (Table 1). A property test
guards the paper's implicit claim that 16 bits never saturate for
1024-event frames (max votes per voxel per keyframe <= #events between
keyframes, bounded in practice by a few thousand).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.camera import CameraModel
from repro.core.geometry import depth_planes

Array = jax.Array

DSI_STORE_DTYPE = jnp.int16  # paper Table 1: DSI scores, 16-bit integer
DSI_ACCUM_DTYPE = jnp.int32  # accumulation dtype (saturation-checked on store)


def store_clip_bounds() -> tuple[float, float]:
    """The (min, max) saturating-store clamp as float literals.

    Single source of truth shared by `to_storage` and the fused Pallas
    kernel's in-VMEM int16 store — and the pair the quantization-contract
    linter expects as clamp provenance on any float->int16 cast
    (`EMVSQuantPolicy.sanctioned_clip_bounds()` contains it via the
    Table-1 'dsi' format).
    """
    info = jnp.iinfo(DSI_STORE_DTYPE)
    return float(info.min), float(info.max)


@dataclasses.dataclass(frozen=True)
class DSIConfig:
    width: int = 240
    height: int = 180
    num_planes: int = 128
    z_min: float = 0.5
    z_max: float = 5.0
    inverse_depth: bool = True

    @property
    def shape(self) -> tuple[int, int, int]:
        return (self.num_planes, self.height, self.width)

    def planes(self) -> Array:
        return depth_planes(self.z_min, self.z_max, self.num_planes, self.inverse_depth)

    @staticmethod
    def for_camera(cam: CameraModel, num_planes: int = 128, z_min: float = 0.5,
                   z_max: float = 5.0, inverse_depth: bool = True) -> "DSIConfig":
        return DSIConfig(cam.width, cam.height, num_planes, z_min, z_max, inverse_depth)


def zeros(cfg: DSIConfig, dtype=DSI_ACCUM_DTYPE) -> Array:
    return jnp.zeros(cfg.shape, dtype=dtype)


def to_storage(dsi: Array) -> Array:
    """int32 accumulator -> int16 storage with saturation (RTL-style clip)."""
    info = jnp.iinfo(DSI_STORE_DTYPE)
    return jnp.clip(dsi, info.min, info.max).astype(DSI_STORE_DTYPE)


def from_storage(dsi: Array) -> Array:
    return dsi.astype(DSI_ACCUM_DTYPE)


def storage_roundtrip(dsi: Array) -> Array:
    """Apply int16 store semantics to an accumulator DSI (any leading dims).

    Voting accumulates in int32 (or float32 for bilinear); the device
    checkpoints DSI scores as int16 (Table 1). This clips exactly like the
    RTL store path and returns the accumulator dtype, so downstream
    detection sees the quantized scores. Elementwise, hence safe for both
    a single (Nz, h, w) volume and a batched (S, Nz, h, w) sweep.
    """
    return from_storage(to_storage(dsi))


def saturation_fraction(dsi: Array) -> Array:
    """Fraction of voxels that would clip at int16 — paper's 16b adequacy claim."""
    info = jnp.iinfo(DSI_STORE_DTYPE)
    return jnp.mean((dsi > info.max) | (dsi < info.min))


def store_saturation_fraction(dsi: Array) -> Array:
    """Fraction of voxels sitting AT the int16 store limits (inclusive).

    `saturation_fraction` asks the pre-store question ("would this
    accumulator clip?") and is identically zero on anything that already
    went through `storage_roundtrip`. Live streams only ever see stored
    volumes, so the streaming monitor uses this boundary-inclusive form:
    a voxel at exactly ±int16 max either clipped or is about to, and
    either way the paper's "16 bits never saturate" claim is at risk.
    Elementwise, so batched (S, Nz, h, w) sweeps work unchanged.
    """
    info = jnp.iinfo(DSI_STORE_DTYPE)
    return jnp.mean((dsi >= info.max) | (dsi <= info.min))
