"""Scene-structure detection (D): DSI -> semi-dense depth map.

Following EMVS [Rebecq IJCV'18] / the paper's stage D:
  1. confidence map c(x,y) = max_z DSI, z*(x,y) = argmax_z;
  2. adaptive Gaussian thresholding of c selects semi-dense pixels;
  3. sub-voxel depth refinement by parabola fit around the argmax
     (in inverse-depth index space);
  4. optional 2D median filter on the depth map.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class DepthMap(NamedTuple):
    depth: Array  # (h, w) float32; undefined where mask is False
    mask: Array  # (h, w) bool — semi-dense support
    confidence: Array  # (h, w) float32 ray-density score


def gaussian_kernel1d(sigma: float, radius: int) -> Array:
    x = jnp.arange(-radius, radius + 1, dtype=jnp.float32)
    k = jnp.exp(-0.5 * (x / sigma) ** 2)
    return k / jnp.sum(k)


def gaussian_blur(img: Array, sigma: float = 2.0, radius: int = 5) -> Array:
    """Separable Gaussian blur with edge padding, (h, w) -> (h, w)."""
    k = gaussian_kernel1d(sigma, radius)
    pad = [(radius, radius)]
    row = jnp.pad(img, pad + [(0, 0)], mode="edge")
    img = jax.vmap(lambda col: jnp.convolve(col, k, mode="valid"), in_axes=1, out_axes=1)(row)
    col = jnp.pad(img, [(0, 0)] + pad, mode="edge")
    img = jax.vmap(lambda r: jnp.convolve(r, k, mode="valid"), in_axes=0, out_axes=0)(col)
    return img


@partial(jax.jit, static_argnames=("adaptive_radius",))
def detect_structure_from(
    conf: Array,
    zf: Array,
    planes: Array,
    *,
    threshold_c: float = 6.0,
    adaptive_sigma: float = 2.5,
    adaptive_radius: int = 5,
    min_votes: float = 3.0,
) -> DepthMap:
    """Detection tail from a precomputed depth reduction.

    `conf` (h, w) is the depth-axis max of the (stored) DSI and `zf`
    (h, w) the parabola-refined argmax — exactly what the fused
    backproject_vote kernel (or `kernels/local_max`) emits. This is the
    shared back half of `detect_structure`: adaptive Gaussian threshold
    mask + piecewise-linear depth interpolation between plane centres.
    Keeping one implementation means the fused-kernel path and the XLA
    argmax path cannot drift in the post-reduction math.
    """
    conf = conf.astype(jnp.float32)
    zf = zf.astype(jnp.float32)
    local_mean = gaussian_blur(conf, adaptive_sigma, adaptive_radius)
    mask = (conf > local_mean + threshold_c) & (conf >= min_votes)

    # interpolate depth between plane centres (piecewise-linear in index)
    nz = planes.shape[0]
    z_lo = jnp.clip(jnp.floor(zf).astype(jnp.int32), 0, nz - 1)
    z_hi = jnp.clip(z_lo + 1, 0, nz - 1)
    frac = zf - z_lo.astype(jnp.float32)
    depth = planes[z_lo] * (1.0 - frac) + planes[z_hi] * frac
    return DepthMap(depth=depth, mask=mask, confidence=conf)


@partial(jax.jit, static_argnames=("adaptive_radius",))
def detect_structure(
    dsi: Array,
    planes: Array,
    *,
    threshold_c: float = 6.0,
    adaptive_sigma: float = 2.5,
    adaptive_radius: int = 5,
    min_votes: float = 3.0,
    refine_subvoxel: bool = True,
) -> DepthMap:
    """DSI (Nz, h, w) -> semi-dense DepthMap at the reference view.

    Adaptive Gaussian threshold: pixel kept iff
        c(x,y) > blur(c)(x,y) + threshold_c   and   c(x,y) >= min_votes.
    """
    dsi_f = dsi.astype(jnp.float32)
    conf = jnp.max(dsi_f, axis=0)  # (h, w)
    zidx = jnp.argmax(dsi_f, axis=0)  # (h, w)

    nz = dsi.shape[0]
    if refine_subvoxel:
        zm = jnp.clip(zidx - 1, 0, nz - 1)
        zp = jnp.clip(zidx + 1, 0, nz - 1)
        hh, ww = jnp.meshgrid(
            jnp.arange(dsi.shape[1]), jnp.arange(dsi.shape[2]), indexing="ij"
        )
        cm = dsi_f[zm, hh, ww]
        c0 = dsi_f[zidx, hh, ww]
        cp = dsi_f[zp, hh, ww]
        denom = cm - 2.0 * c0 + cp
        offset = jnp.where(jnp.abs(denom) > 1e-6, 0.5 * (cm - cp) / denom, 0.0)
        offset = jnp.clip(offset, -0.5, 0.5)
        zf = zidx.astype(jnp.float32) + offset
    else:
        zf = zidx.astype(jnp.float32)

    return detect_structure_from(
        conf, zf, planes,
        threshold_c=threshold_c, adaptive_sigma=adaptive_sigma,
        adaptive_radius=adaptive_radius, min_votes=min_votes,
    )


def detect_and_filter(
    dsi: Array,
    planes: Array,
    *,
    threshold_c: float = 6.0,
    min_votes: float = 3.0,
    median_filter: bool = True,
) -> DepthMap:
    """D (+ optional 3x3 median) for one DSI volume.

    Single entry point used by both the per-segment and the batched
    segment-sweep pipeline paths so the post-voting math cannot drift
    between them.
    """
    dm = detect_structure(dsi, planes, threshold_c=threshold_c, min_votes=min_votes)
    if median_filter:
        dm = DepthMap(median_filter3(dm.depth, dm.mask), dm.mask, dm.confidence)
    return dm


def detect_and_filter_from(
    conf: Array,
    zf: Array,
    planes: Array,
    *,
    threshold_c: float = 6.0,
    min_votes: float = 3.0,
    median_filter: bool = True,
) -> DepthMap:
    """`detect_and_filter` for callers that already hold (conf, zf).

    The fused backproject_vote kernel performs the depth max/argmax +
    parabola refinement against the VMEM-resident DSI block; this entry
    applies the identical post-reduction tail (threshold mask, depth
    interpolation, optional median), so the fused and unfused sweeps
    share every instruction after the reduction.
    """
    dm = detect_structure_from(conf, zf, planes,
                               threshold_c=threshold_c, min_votes=min_votes)
    if median_filter:
        dm = DepthMap(median_filter3(dm.depth, dm.mask), dm.mask, dm.confidence)
    return dm


def median_filter3(depth: Array, mask: Array) -> Array:
    """3x3 median over valid neighbours (cheap shift-stack formulation)."""
    shifts = []
    big = jnp.float32(jnp.inf)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            d = jnp.roll(jnp.roll(depth, dy, axis=0), dx, axis=1)
            m = jnp.roll(jnp.roll(mask, dy, axis=0), dx, axis=1)
            shifts.append(jnp.where(m, d, big))
    stack = jnp.stack(shifts, axis=0)  # (9, h, w)
    valid_count = jnp.sum(stack < big, axis=0)
    sorted_stack = jnp.sort(stack, axis=0)
    mid = jnp.maximum((valid_count - 1) // 2, 0)
    hh, ww = jnp.meshgrid(
        jnp.arange(depth.shape[0]), jnp.arange(depth.shape[1]), indexing="ij"
    )
    med = sorted_stack[mid, hh, ww]
    return jnp.where(mask & (valid_count > 0), med, depth)
