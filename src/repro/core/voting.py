"""Volumetric ray-counting (R): DSI voting.

Three formulations, all numerically reconciled by tests:

  1. `vote_scatter`       — the CPU/GPU-idiomatic port: scatter-add into
                            the volume (what the FPGA's Vote Execute Unit
                            does with DRAM read-modify-write). Reference
                            semantics; slow on TPU (random HBM traffic).
  2. `vote_onehot_matmul` — the TPU-native reformulation (DESIGN.md §2):
                            per depth plane, votes = Ox^T @ Oy with
                            one-hot (nearest) or two-hot (bilinear) event
                            row encodings. Runs on the MXU; no scatter.
  3. kernels/backproject_vote — the Pallas kernel implementing (2) fused
                            with P(Z0->Zi), tiled for VMEM.

Both nearest and bilinear voting are exact in formulation (2):
bilinear 4-neighbour weights are separable, (1-fx,fx) ⊗ (1-fy,fy).

Out-of-bounds projections are dropped ("projection missing judgement"
performed by the paper's Nearest Voxel Finder).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.quant.fixed_point import round_half_away

Array = jax.Array


def _sanitize(coord: Array) -> Array:
    """Clamp non-finite / absurd coords to a harmless far-out-of-bounds value.

    Invalid (masked) events are parked at -1e4 by the data pipeline, but a
    near-singular homography denominator can still produce inf/NaN; any
    such coordinate must fail the bounds check rather than poison the
    votes (0 * NaN = NaN). Also keeps round()->int32 overflow-free.
    """
    c = jnp.where(jnp.isfinite(coord), coord, jnp.float32(-1e6))
    return jnp.clip(c, -1e6, 1e6)


def _round_half_up(x: Array) -> Array:
    """RTL-style nearest-pixel rounding (floor(x+0.5)); jnp.round would be
    half-to-even and disagree with the hardware convention at exact .5."""
    return jnp.floor(x + 0.5)


def _bounds_mask_nearest(xi: Array, yi: Array, w: int, h: int) -> Array:
    xr, yr = _round_half_up(xi), _round_half_up(yi)
    return (xr >= 0) & (xr <= w - 1) & (yr >= 0) & (yr <= h - 1)


def _bounds_mask_bilinear(xi: Array, yi: Array, w: int, h: int) -> Array:
    x0, y0 = jnp.floor(xi), jnp.floor(yi)
    return (x0 >= 0) & (x0 + 1 <= w - 1) & (y0 >= 0) & (y0 + 1 <= h - 1)


# ---------------------------------------------------------------------------
# 1. Scatter formulation (algorithmic baseline; FPGA Vote-Execute semantics)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("w", "h", "mode"))
def vote_scatter(
    dsi: Array, x_i: Array, y_i: Array, *, w: int, h: int, mode: str = "nearest",
    weights: Array | None = None,
) -> Array:
    """Scatter-add votes into dsi (Nz, h, w).

    x_i, y_i: (Nz, E) projected coords per plane. mode: nearest|bilinear.
    weights: optional (Nz, E) per-event vote weight (default 1).
    """
    x_i, y_i = _sanitize(x_i), _sanitize(y_i)
    nz = dsi.shape[0]
    base = jnp.ones(x_i.shape, dtype=jnp.float32) if weights is None else weights
    if mode == "nearest":
        m = _bounds_mask_nearest(x_i, y_i, w, h)
        xr = jnp.clip(_round_half_up(x_i).astype(jnp.int32), 0, w - 1)
        yr = jnp.clip(_round_half_up(y_i).astype(jnp.int32), 0, h - 1)
        votes = jnp.where(m, base, 0.0)
        if dsi.dtype in (jnp.int16, jnp.int32):
            votes = votes.astype(dsi.dtype)
        z_idx = jnp.broadcast_to(jnp.arange(nz, dtype=jnp.int32)[:, None], x_i.shape)
        return dsi.at[z_idx, yr, xr].add(votes)
    elif mode == "bilinear":
        m = _bounds_mask_bilinear(x_i, y_i, w, h)
        x0 = jnp.clip(jnp.floor(x_i).astype(jnp.int32), 0, w - 2)
        y0 = jnp.clip(jnp.floor(y_i).astype(jnp.int32), 0, h - 2)
        fx = x_i - x0.astype(x_i.dtype)
        fy = y_i - y0.astype(y_i.dtype)
        z_idx = jnp.broadcast_to(jnp.arange(nz, dtype=jnp.int32)[:, None], x_i.shape)
        wmask = jnp.where(m, base, 0.0)
        out = dsi.astype(jnp.float32) if dsi.dtype != jnp.float32 else dsi
        for dx, dy, wgt in (
            (0, 0, (1 - fx) * (1 - fy)),
            (1, 0, fx * (1 - fy)),
            (0, 1, (1 - fx) * fy),
            (1, 1, fx * fy),
        ):
            out = out.at[z_idx, y0 + dy, x0 + dx].add(wmask * wgt)
        return out if dsi.dtype == jnp.float32 else out.astype(dsi.dtype)
    raise ValueError(f"unknown voting mode: {mode}")


# ---------------------------------------------------------------------------
# 2. One-hot matmul formulation (TPU-native; runs on the MXU)
# ---------------------------------------------------------------------------


def onehot_rows_nearest(coord: Array, size: int, valid: Array) -> Array:
    """(..., E) coords -> (..., E, size) one-hot rows; invalid rows all-zero."""
    idx = _round_half_up(coord).astype(jnp.int32)
    grid = jnp.arange(size, dtype=jnp.int32)
    rows = (idx[..., None] == grid).astype(jnp.float32)
    return rows * valid[..., None].astype(jnp.float32)


def twohot_rows_bilinear(coord: Array, size: int, valid: Array) -> Array:
    """(..., E) coords -> (..., E, size) two-hot rows with (1-f, f) weights."""
    c0 = jnp.floor(coord).astype(jnp.int32)
    f = (coord - c0.astype(coord.dtype)).astype(jnp.float32)
    grid = jnp.arange(size, dtype=jnp.int32)
    lo = (c0[..., None] == grid).astype(jnp.float32) * (1.0 - f)[..., None]
    hi = ((c0 + 1)[..., None] == grid).astype(jnp.float32) * f[..., None]
    return (lo + hi) * valid[..., None].astype(jnp.float32)


@partial(jax.jit, static_argnames=("w", "h", "mode"))
def vote_onehot_matmul(
    dsi: Array, x_i: Array, y_i: Array, *, w: int, h: int, mode: str = "nearest",
    weights: Array | None = None,
) -> Array:
    """Per-plane votes = Oy^T @ Ox  ∈ (h, w), accumulated into dsi (Nz,h,w).

    The contraction over events is a matmul — the systolic-array
    reformulation of the FPGA's scatter unit (DESIGN.md §2).
    """
    x_i, y_i = _sanitize(x_i), _sanitize(y_i)
    if mode == "nearest":
        valid = _bounds_mask_nearest(x_i, y_i, w, h)
        ox = onehot_rows_nearest(x_i, w, valid)  # (Nz, E, w)
        oy = onehot_rows_nearest(y_i, h, valid)  # (Nz, E, h)
    elif mode == "bilinear":
        valid = _bounds_mask_bilinear(x_i, y_i, w, h)
        ox = twohot_rows_bilinear(x_i, w, valid)
        oy = twohot_rows_bilinear(y_i, h, valid)
    else:
        raise ValueError(f"unknown voting mode: {mode}")
    if weights is not None:
        ox = ox * weights[..., None]
    votes = jnp.einsum("zeh,zew->zhw", oy, ox)  # MXU contraction over events
    if dsi.dtype in (jnp.int16, jnp.int32):
        # RTL rounding convention: half away from zero, matching the
        # fixed-point quantizers — jnp.round would be half-to-even and
        # disagree with quant/fixed_point at exact half-integer votes
        votes = round_half_away(votes).astype(dsi.dtype)
    return dsi + votes
