"""Pinhole camera model with radial-tangential distortion.

The DAVIS240C sensor used by the paper is 240x180. Intrinsics follow the
event-camera dataset calibration format [Mueggler et al., IJRR'17]:
fx, fy, cx, cy and distortion (k1, k2, p1, p2, k3).

Distortion correction is applied *per event, in streaming order* (the
paper's first rescheduling: correction moves BEFORE aggregation so events
arrive at the aggregation stage already rectified).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array

# DAVIS240C calibration from the event-camera dataset (slider sequences).
DAVIS240_WIDTH = 240
DAVIS240_HEIGHT = 180


@dataclasses.dataclass(frozen=True)
class CameraModel:
    """Intrinsics + distortion for a pinhole camera."""

    width: int = DAVIS240_WIDTH
    height: int = DAVIS240_HEIGHT
    fx: float = 199.0
    fy: float = 199.0
    cx: float = 132.0
    cy: float = 110.0
    # radial-tangential (plumb-bob) distortion
    k1: float = 0.0
    k2: float = 0.0
    p1: float = 0.0
    p2: float = 0.0
    k3: float = 0.0

    @property
    def K(self) -> Array:
        """3x3 intrinsic matrix."""
        return jnp.array(
            [[self.fx, 0.0, self.cx], [0.0, self.fy, self.cy], [0.0, 0.0, 1.0]],
            dtype=jnp.float32,
        )

    @property
    def K_inv(self) -> Array:
        return jnp.array(
            [
                [1.0 / self.fx, 0.0, -self.cx / self.fx],
                [0.0, 1.0 / self.fy, -self.cy / self.fy],
                [0.0, 0.0, 1.0],
            ],
            dtype=jnp.float32,
        )

    def has_distortion(self) -> bool:
        return any(abs(v) > 0 for v in (self.k1, self.k2, self.p1, self.p2, self.k3))


def project(cam: CameraModel, points_cam: Array) -> Array:
    """Project 3D points in camera frame -> pixel coordinates (no distortion).

    points_cam: (..., 3). Returns (..., 2) pixel coords (x, y).
    """
    z = points_cam[..., 2]
    x = cam.fx * points_cam[..., 0] / z + cam.cx
    y = cam.fy * points_cam[..., 1] / z + cam.cy
    return jnp.stack([x, y], axis=-1)


def unproject(cam: CameraModel, pixels: Array, depth: Array) -> Array:
    """Back-project pixels at given depth -> 3D points in camera frame.

    pixels: (..., 2); depth: broadcastable to (...,). Returns (..., 3).
    """
    x = (pixels[..., 0] - cam.cx) / cam.fx
    y = (pixels[..., 1] - cam.cy) / cam.fy
    return jnp.stack([x * depth, y * depth, jnp.broadcast_to(depth, x.shape)], axis=-1)


def distort_normalized(cam: CameraModel, xn: Array, yn: Array) -> tuple[Array, Array]:
    """Apply plumb-bob distortion to normalized image coordinates."""
    r2 = xn * xn + yn * yn
    radial = 1.0 + r2 * (cam.k1 + r2 * (cam.k2 + r2 * cam.k3))
    xd = xn * radial + 2.0 * cam.p1 * xn * yn + cam.p2 * (r2 + 2.0 * xn * xn)
    yd = yn * radial + cam.p1 * (r2 + 2.0 * yn * yn) + 2.0 * cam.p2 * xn * yn
    return xd, yd


@partial(jax.jit, static_argnums=0)
def undistort_events(cam: CameraModel, xy: Array, num_iters: int = 5) -> Array:
    """Streaming event distortion correction (paper stage: before aggregation).

    Iterative inversion of the plumb-bob model (the standard fixed-point
    scheme used by OpenCV undistortPoints). xy: (..., 2) raw pixel coords.
    Returns rectified pixel coords, same shape.
    """
    if not cam.has_distortion():
        return xy
    xd = (xy[..., 0] - cam.cx) / cam.fx
    yd = (xy[..., 1] - cam.cy) / cam.fy

    def body(_, xn_yn):
        xn, yn = xn_yn
        xdd, ydd = distort_normalized(cam, xn, yn)
        # fixed-point update: xn <- xd - (distortion-induced offset)
        return (xn + (xd - xdd), yn + (yd - ydd))

    xn, yn = jax.lax.fori_loop(0, num_iters, body, (xd, yd))
    return jnp.stack([xn * cam.fx + cam.cx, yn * cam.fy + cam.cy], axis=-1)


def in_bounds_mask(cam: CameraModel, xy: Array, margin: float = 0.0) -> Array:
    """Valid-pixel mask ('projection missing judgement' in the paper)."""
    x, y = xy[..., 0], xy[..., 1]
    return (
        (x >= margin)
        & (x <= cam.width - 1 - margin)
        & (y >= margin)
        & (y <= cam.height - 1 - margin)
    )
