"""Architecture configs: the 10 assigned LM-family architectures + the
paper's own EMVS workload, as selectable configs (``--arch <id>``).

Every entry records its public source; smoke tests instantiate
``cfg.reduced()`` (same family, tiny dims) and run a real step on CPU;
the full configs are exercised via the dry-run (ShapeDtypeStruct only).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "get_config", "list_archs",
           "ARCH_IDS"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # which layers are MoE: "all" | "alternate" (odd layers dense)
    layout: str = "all"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mlp_variant: str = "swiglu"  # swiglu | gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid interleave: repeating super-block, e.g. ("attn",) + ("mamba",)*7
    block_pattern: Optional[tuple[str, ...]] = None
    # modality frontend stub (assignment: frontends are stubs; input_specs()
    # provides precomputed frame/patch embeddings)
    frontend: Optional[str] = None  # None | "audio_frames" | "vision_patches"
    n_frontend_tokens: int = 0
    # sharding-driven head padding (§Perf H1): extra q/kv heads whose
    # outputs are masked to zero after attention — exact fwd AND bwd
    # (masked outputs kill both the padded wo contribution and every
    # gradient into padded projections), but head counts become divisible
    # by the TP degree so attention shards instead of replicating.
    head_pad: int = 0
    kv_head_pad: int = 0
    source: str = ""

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def n_heads_eff(self) -> int:
        """Head count including sharding pad (projection/layout size)."""
        return self.n_heads + self.head_pad

    @property
    def n_kv_heads_eff(self) -> int:
        return self.n_kv_heads + self.kv_head_pad

    def pad_heads_to(self, tp: int) -> "ArchConfig":
        """Pad q/kv head counts up to multiples of the TP degree.

        No-op when already divisible. Padded heads are exact-zero in the
        model function (outputs masked), so this is a pure layout
        transform that converts TP-replicated attention into sharded
        attention (§Perf H1)."""
        if self.n_heads == 0:
            return self

        def pad(n: int) -> int:
            return (-n) % tp

        hp, kp = pad(self.n_heads), pad(self.n_kv_heads)
        if hp == 0 and kp == 0:
            return self
        # groups must stay integral: (hq+hp) % (hkv+kp) == 0
        hq_p, hkv_p = self.n_heads + hp, self.n_kv_heads + kp
        while hq_p % hkv_p:
            hq_p += tp
        return dataclasses.replace(self, head_pad=hq_p - self.n_heads,
                                   kv_head_pad=kp)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def full_attention(self) -> bool:
        """True if the arch has quadratic attention only (no sub-quadratic
        path) — such archs skip the long_500k cell per the assignment."""
        return self.family not in ("ssm", "hybrid")

    def pattern(self) -> tuple[str, ...]:
        """Per-super-block layer kinds; scan runs over super-blocks."""
        if self.block_pattern is not None:
            return self.block_pattern
        if self.family == "ssm":
            return ("mamba",)
        return ("attn",)

    def n_superblocks(self) -> int:
        p = len(self.pattern())
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return self.n_layers // p

    def active_params(self) -> int:
        """Approximate active (per-token) parameter count."""
        d, hd = self.d_model, self.head_dim
        per_attn = d * (self.n_heads * hd + 2 * self.n_kv_heads * hd) + self.n_heads * hd * d
        if self.ssm is not None:
            di = self.ssm.d_inner(d)
            nh = self.ssm.num_heads(d)
            # in_proj (z,x,B,C,dt) + out_proj + conv + head params
            per_mamba = (d * (2 * di + 2 * self.ssm.d_state + nh)
                         + di * d + self.ssm.conv_kernel * (di + 2 * self.ssm.d_state)
                         + 3 * nh)
        else:
            per_mamba = 0
        n_mats = 3 if self.mlp_variant == "swiglu" else 2
        if self.moe is not None:
            per_mlp = n_mats * d * self.moe.d_ff_expert * (
                self.moe.top_k + self.moe.num_shared_experts)
        else:
            per_mlp = n_mats * d * self.d_ff
        pat = self.pattern()
        n_sb = self.n_superblocks()
        total = 0
        for i, kind in enumerate(pat):
            mlp = per_mlp
            if self.moe is not None and self.moe.layout == "alternate" and i % 2 == 1:
                mlp = 3 * d * self.d_ff
            total += (per_attn if kind == "attn" else per_mamba) + mlp + 2 * d
        total *= n_sb
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def total_params(self) -> int:
        """Approximate total parameter count (MoE: all experts)."""
        if self.moe is None:
            return self.active_params()
        d = self.d_model
        per_moe_all = 3 * d * self.moe.d_ff_expert * (
            self.moe.num_experts + self.moe.num_shared_experts)
        per_moe_active = 3 * d * self.moe.d_ff_expert * (
            self.moe.top_k + self.moe.num_shared_experts)
        pat = self.pattern()
        n_moe_layers = sum(
            1 for i, _ in enumerate(pat)
            if not (self.moe.layout == "alternate" and i % 2 == 1)
        ) * self.n_superblocks()
        return self.active_params() + n_moe_layers * (per_moe_all - per_moe_active)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-reduced",
            family=self.family,
            n_layers=len(self.pattern()),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=512,
            d_head=16,
            qk_norm=self.qk_norm,
            qkv_bias=self.qkv_bias,
            mlp_variant=self.mlp_variant,
            tie_embeddings=self.tie_embeddings,
            block_pattern=self.block_pattern,
            frontend=self.frontend,
            n_frontend_tokens=8 if self.frontend else 0,
            source="reduced-for-smoke",
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=8, top_k=min(self.moe.top_k, 2), d_ff_expert=32,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                layout=self.moe.layout)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2,
                                  conv_kernel=4, chunk_size=32)
        return ArchConfig(**kw)


def _registry() -> dict[str, ArchConfig]:
    from repro.configs import archs

    return archs.REGISTRY


def get_config(name: str) -> ArchConfig:
    reg = _registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(reg)}")
    return reg[name]


def list_archs() -> list[str]:
    return sorted(_registry())


ARCH_IDS = [
    "kimi-k2-1t-a32b",
    "deepseek-moe-16b",
    "musicgen-large",
    "stablelm-3b",
    "qwen3-8b",
    "starcoder2-15b",
    "qwen1.5-4b",
    "jamba-1.5-large-398b",
    "llava-next-mistral-7b",
    "mamba2-2.7b",
]
