"""Config module for --arch musicgen-large (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "musicgen-large"
CONFIG = get_config(ARCH_ID)
