"""Config module for --arch qwen1.5-4b (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "qwen1.5-4b"
CONFIG = get_config(ARCH_ID)
