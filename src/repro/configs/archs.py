"""The 10 assigned architectures (public literature) + the paper's EMVS
workload. One module so the registry is greppable; per-arch modules under
``repro/configs/<id>.py`` re-export their entry for ``--arch`` ergonomics.
"""
from __future__ import annotations

from repro.configs import ArchConfig, MoEConfig, SSMConfig

REGISTRY: dict[str, ArchConfig] = {}


def _add(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


# --- MoE -------------------------------------------------------------------

KIMI_K2 = _add(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,  # 7168 / 64
    d_ff=2048,
    vocab_size=163840,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1),
    source="arXiv:2501.kimi2 (paper-table; unverified). Deviation: K2's "
           "first dense layer is modelled as MoE to keep the layer stack "
           "scan-homogeneous (noted in DESIGN.md).",
))

DEEPSEEK_MOE = _add(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2),
    source="arXiv:2401.06066 (hf). Fine-grained 2-shared + 64-routed top-6. "
           "Deviation: first dense layer modelled as MoE (scan-homogeneous).",
))

# --- dense -----------------------------------------------------------------

MUSICGEN = _add(ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_variant="gelu",
    frontend="audio_frames",
    n_frontend_tokens=0,  # decoder over EnCodec tokens; embeddings stubbed
    source="arXiv:2306.05284 (hf). Decoder-only over EnCodec codes; the "
           "EnCodec frontend is a stub per assignment (input_specs provides "
           "precomputed frame embeddings).",
))

STABLELM = _add(ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    source="hf:stabilityai/stablelm-2 family (unverified).",
))

QWEN3 = _add(ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (hf). qk_norm + GQA kv=8.",
))

STARCODER2 = _add(ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_head=128,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,
    mlp_variant="gelu",
    source="arXiv:2402.19173 (hf). GQA kv=4, RoPE.",
))

QWEN15 = _add(ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_head=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5 family (hf). QKV bias.",
))

# --- hybrid / ssm ----------------------------------------------------------

JAMBA = _add(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                  num_shared_experts=0, layout="alternate"),
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=256),
    # Jamba period-8 super-block: attention at position 4 of 8 (1:7)
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    source="arXiv:2403.19887 (hf). MoE every other layer (top-2 of 16); "
           "Mamba layers use our Mamba-2 SSD cell (Jamba ships Mamba-1; "
           "adaptation noted in DESIGN.md §Arch-applicability).",
))

LLAVA = _add(ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=32000,
    frontend="vision_patches",
    n_frontend_tokens=2880,  # anyres 4 tiles + base, 576 each
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (unverified). Mistral-7B "
           "backbone; anyres vision tower stubbed (patch embeddings input).",
))

MAMBA2 = _add(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060 (unverified). SSD (state-space duality).",
))

# --- the paper's own workload ----------------------------------------------
# Not an LM: kept in the same registry so `--arch eventor-davis240` selects
# the EMVS pipeline in the launcher/dry-run (see configs/shapes.py).

EVENTOR = _add(ArchConfig(
    name="eventor-davis240",
    family="emvs",
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    source="The paper: DAVIS240 (240x180) event camera, 1024-event frames, "
           "DSI 240x180xNz. See repro.core.",
))
