"""Config module for --arch kimi-k2-1t-a32b (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "kimi-k2-1t-a32b"
CONFIG = get_config(ARCH_ID)
