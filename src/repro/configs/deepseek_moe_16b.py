"""Config module for --arch deepseek-moe-16b (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "deepseek-moe-16b"
CONFIG = get_config(ARCH_ID)
