"""Config module for --arch jamba-1.5-large-398b (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "jamba-1.5-large-398b"
CONFIG = get_config(ARCH_ID)
