"""Config module for --arch qwen3-8b (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "qwen3-8b"
CONFIG = get_config(ARCH_ID)
