"""Config module for --arch mamba2-2.7b (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "mamba2-2.7b"
CONFIG = get_config(ARCH_ID)
