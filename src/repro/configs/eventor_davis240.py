"""Config module for --arch eventor-davis240 (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "eventor-davis240"
CONFIG = get_config(ARCH_ID)
