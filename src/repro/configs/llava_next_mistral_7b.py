"""Config module for --arch llava-next-mistral-7b (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "llava-next-mistral-7b"
CONFIG = get_config(ARCH_ID)
