"""Canonical input-shape cells and ShapeDtypeStruct input specs.

LM cells (per assignment; seq_len x global_batch):
    train_4k     seq 4096,    batch 256   -> train_step
    prefill_32k  seq 32768,   batch 32    -> serve prefill
    decode_32k   seq 32768,   batch 128   -> serve_step (1 new token, KV=seq)
    long_500k    seq 524288,  batch 1     -> decode; SSM/hybrid only

EMVS cells (the paper's workload):
    emvs_rt      1 frame  x 1024 events  (real-time packet)
    emvs_seg     256 frames x 1024 events (one key-frame segment sweep)

``input_specs(cfg, cell)`` returns {name: ShapeDtypeStruct} — weak-type
correct, shardable, zero allocation (the dry-run contract).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig

__all__ = ["ShapeCell", "LM_CELLS", "EMVS_CELLS", "cells_for", "input_specs",
           "cell_skipped"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


LM_CELLS = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

EMVS_CELLS = {
    "emvs_rt": ShapeCell("emvs_rt", "emvs", 1024, 1),  # events/frame, frames
    "emvs_seg": ShapeCell("emvs_seg", "emvs", 1024, 256),
}


def cell_skipped(cfg: ArchConfig, cell: ShapeCell) -> str | None:
    """Return a skip reason, or None if the cell runs for this arch."""
    if cfg.family == "emvs":
        return None if cell.kind == "emvs" else "emvs arch has no LM cells"
    if cell.kind == "emvs":
        return "LM arch has no EMVS cells"
    if cell.name == "long_500k" and cfg.full_attention:
        return ("pure full-attention arch: no sub-quadratic path at 500k "
                "context (assignment skip rule; DESIGN.md §Arch-applicability)")
    return None


def cells_for(cfg: ArchConfig) -> list[ShapeCell]:
    table = EMVS_CELLS if cfg.family == "emvs" else LM_CELLS
    return [c for c in table.values() if cell_skipped(cfg, c) is None]


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    f32 = jnp.float32
    i32 = jnp.int32
    if cfg.family == "emvs":
        frames = cell.global_batch
        e = cell.seq_len
        nz = 256  # production DSI depth resolution for the dry-run
        return {
            "xy": jax.ShapeDtypeStruct((frames, e, 2), f32),
            "valid": jax.ShapeDtypeStruct((frames, e), f32),
            "H": jax.ShapeDtypeStruct((frames, 3, 3), f32),
            "phi": jax.ShapeDtypeStruct((frames, nz, 3), f32),
        }

    b, s = cell.global_batch, cell.seq_len
    n_front = cfg.n_frontend_tokens
    if cell.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.frontend == "vision_patches" and n_front:
            # patch embeddings occupy the first n_front positions of s
            specs["frontend_embed"] = jax.ShapeDtypeStruct((b, n_front, cfg.d_model), f32)
        elif cfg.frontend == "audio_frames":
            # EnCodec frame embeddings for the full sequence (stubbed frontend)
            specs["frontend_embed"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)
        return specs
    if cell.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend == "vision_patches" and n_front:
            specs["frontend_embed"] = jax.ShapeDtypeStruct((b, n_front, cfg.d_model), f32)
        elif cfg.frontend == "audio_frames":
            specs["frontend_embed"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)
        return specs
    if cell.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.frontend == "audio_frames":
            specs["frontend_embed"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model), f32)
        return specs
    raise ValueError(cell.kind)
