"""Config module for --arch stablelm-3b (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "stablelm-3b"
CONFIG = get_config(ARCH_ID)
