"""Config module for --arch starcoder2-15b (see configs/archs.py)."""
from repro.configs import get_config

ARCH_ID = "starcoder2-15b"
CONFIG = get_config(ARCH_ID)
