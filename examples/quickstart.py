"""Quickstart: event-based multi-view stereo in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import EMVSOptions, run_emvs
from repro.events.aggregation import aggregate
from repro.events.simulator import (
    SceneConfig, absrel, ground_truth_depth, make_scene, make_trajectory,
    simulate_events,
)

# 1. a DAVIS240-like camera observing three textured planes
cam = CameraModel()
scene = make_scene(SceneConfig(name="simulation_3planes", points_per_plane=300))
traj = make_trajectory("simulation_3planes", num_steps=32)

# 2. simulate the event stream + aggregate into 1024-event frames
events = simulate_events(cam, scene, traj, noise_fraction=0.02)
frames = aggregate(cam, events, traj)
print(f"{int(events.valid.sum())} events -> {frames.xy.shape[0]} frames")

# 3. run EMVS: back-project, vote the DSI, detect structure, build the map
dsi_cfg = DSIConfig.for_camera(cam, num_planes=64, z_min=0.6, z_max=4.5)
result = run_emvs(cam, dsi_cfg, frames,
                  EMVSOptions(voting="nearest", formulation="matmul",
                              quantized=True))  # paper Table-1 datapath

# 4. evaluate against ground truth
for seg in result.segments:
    gt, gt_mask = ground_truth_depth(cam, scene, seg.T_w_ref)
    dm = seg.depth_map
    err = float(absrel(dm.depth, dm.mask, gt, gt_mask))
    print(f"segment frames {seg.frame_range}: "
          f"{int(dm.mask.sum())} semi-dense px, AbsRel {err:.4f}")
