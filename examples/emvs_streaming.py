"""Streaming EMVS demo: depth maps while the sensor is still moving.

The offline demo (`emvs_reconstruction.py`) aggregates the whole
recording, then reconstructs. This variant feeds the same event stream
chunk-by-chunk into `EMVSStreamEngine`: key-frame segments close the
moment the K criterion trips, vote on the device while later events are
still arriving (double-buffered dispatch), and depth maps are printed as
they complete. The final result is bit-identical to `run_emvs` on the
default nearest/integer datapath.

    PYTHONPATH=src python examples/emvs_streaming.py \
        [--scene simulation_3walls] [--chunk-frames 2] [--sweep sharded] \
        [--policy adaptive] [--target-latency-ms 50] [--pose-lag 0.1] \
        [--max-stall 32] [--sessions 3] [--out /tmp/emvs_stream.npz]

`--sessions N` (N > 1) simulates an N-camera event rig: each session
gets its own event stream (same scene and trajectory, different sensor
noise), all multiplexed onto ONE `MultiStreamEngine` whose shared
dispatcher coalesces shape-compatible segments from different cameras
into the same device sweep (watch `cross_stream_dispatches` in the
summary). Chunks interleave round-robin across sessions; every
session's reconstruction is verified bit-identical to its own offline
`run_emvs`. The pose-gated flags (`--pose-lag`, `--max-stall`) demo
the single-stream tracker model and require `--sessions 1`.

`--sweep sharded` dispatches each closed-segment bucket through
`repro.distributed.emvs.process_segments_sharded` (segment axis sharded
over all local devices) instead of the serial `lax.map` sweep; results
stay bit-identical on the default nearest/integer datapath.

`--policy` picks how closed segments leave the coalescing queue:
"latency" sweeps every segment the moment it closes (lowest
time-to-depth-map), "throughput" holds segments until the largest S
bucket fills (fewest dispatches, biggest batches — pair with `--sweep
sharded` for cross-device parallelism), "adaptive" (default) never
waits while the device keeps up — a lone closed segment dispatches
solo, an already-queued backlog coalesces — and holds segments to
coalesce once the in-flight queue saturates. The reconstruction is
bit-identical under every policy — only the dispatch schedule moves.

`--max-stall N` (pose-gated mode) bounds the pose-stall queue: if the
tracker falls more than N frames behind the event front, `push` raises
`PoseStallError` instead of buffering unboundedly (the frames are kept;
pushing the missing pose chunks recovers).

`--pose-lag SECONDS` switches the pose source from the fully-known
`Trajectory` oracle to the streamed mode: pose chunks are pushed via
`engine.push_poses` lagging the event front by the given delay (a
tracker running behind the sensor), frames past the pose-lag watermark
stall until their bracketing poses arrive, and `finalize_poses` closes
the pose stream before the flush. The reconstruction stays bit-identical
to the oracle mode — only the latency profile changes.

`--hygiene POLICY` picks the ingest guard (`StreamConfig(hygiene=...)`):
"raise" (default) rejects misordered/overlapping/duplicate/out-of-bounds
chunks with typed errors, "drop" sheds exactly the offending events
(warn + count), "reorder" absorbs misordering inside `--reorder-slack`
seconds bit-identically, "off" disables the guard. Pair with
`--corrupt MODE` to fault-inject one `simulator.corrupt_stream` mode
(shuffle_events, swap_chunks, duplicate_chunk, out_of_bounds, hot_pixel)
into the stream and watch the policy respond: a typed rejection is
printed and the demo stops; surviving policies stream to the end and
report what was shed.

`--target-latency-ms MS` arms the SLO-aware adaptive planner
(`StreamConfig(target_latency_s=...)`, requires `--policy adaptive`): a
cost model predicts the time to drain everything queued and in flight,
and the dispatcher coalesces while the prediction has slack but
dispatches eagerly the moment it would blow the deadline. The model
comes from `cost_table.json` if one has been recorded (run
`python benchmarks/streaming_latency.py`; see
docs/dispatch_planning.md), else a built-in rough affine prior. Each
dispatch prints the PREDICTED drain time next to the ACTUAL wall time
the queue then took to go idle — the honesty check on the model. The
reconstruction stays bit-identical; only WHEN groups dispatch moves.

`--budget-frames N` caps the frame store at N aggregated frames' bytes
(`StreamConfig(frame_store_budget_bytes=...)`): admission stalls
(`--budget-policy stall`, back-pressure) or raises `MemoryBudgetError`
(`--budget-policy reject`; the demo retries via `poll()`) whenever
admitting the next frame would exceed the budget — `live_bytes` never
does, and queued segments are never evicted early. N below the largest
segment's working set (+1 frame) is a fatal, clearly-worded error.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import EMVSOptions, run_emvs
from repro.core.pointcloud import concatenate, radius_outlier_filter
from repro.events.aggregation import EVENTS_PER_FRAME, aggregate
from repro.events.simulator import (
    EVENT_CORRUPTIONS, SceneConfig, absrel, corrupt_stream,
    ground_truth_depth, make_scene, make_trajectory, simulate_events,
    slice_trajectory,
)
from repro.events.stream_hygiene import HygieneConfig, StreamHygieneError
from repro.serving.emvs_stream import (
    EMVSStreamEngine, HYGIENE_POLICIES, MemoryBudgetError, MultiStreamEngine,
    StreamConfig, _FrameStore, iter_event_chunks,
)


def frame_budget_bytes(n_frames: int) -> int:
    """Byte budget equivalent to holding `n_frames` aggregated frames."""
    one = _FrameStore._frame_bytes(
        np.zeros((EVENTS_PER_FRAME, 2), np.float32),
        np.zeros(EVENTS_PER_FRAME, bool), np.float32(0.0),
        np.zeros((3, 3), np.float32), np.zeros(3, np.float32))
    return n_frames * one


def run_multi(args, cam, scene, traj, dsi_cfg, opts) -> None:
    """N-camera rig demo: one shared dispatcher, round-robin interleave,
    per-session offline equivalence check, cross-stream coalescing
    summary."""
    engine = MultiStreamEngine(cam, dsi_cfg, opts,
                               StreamConfig(sweep=args.sweep,
                                            dispatch_policy=args.policy))
    feeds = {}
    for i in range(args.sessions):
        ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=i)
        sess = engine.add_session(f"cam{i}", traj=traj)
        feeds[sess.session_id] = ev
    chunks = {sid: iter_event_chunks(ev, args.chunk_frames * EVENTS_PER_FRAME)
              for sid, ev in feeds.items()}
    print(f"streaming {args.sessions} sessions, round-robin chunks of "
          f"{args.chunk_frames} frame(s)...")
    t0 = time.time()
    while chunks:
        drained = []
        for sid, it in chunks.items():
            chunk = next(it, None)
            if chunk is None:
                drained.append(sid)
                continue
            for seg in engine.push(sid, chunk):
                print(f"  t={time.time() - t0:6.1f}s  [{sid}] "
                      f"keyframe {seg.frame_range}")
        for sid in drained:
            del chunks[sid]
    print("end of all streams -> flush")
    results = engine.flush()
    d = engine.stats["dispatcher"]
    print(f"shared dispatcher: {d['segments']} segments in "
          f"{d['dispatches']} dispatches "
          f"({d['cross_stream_dispatches']} spanning multiple sessions, "
          f"{d['coalesced_segments']} segment(s) coalesced, "
          f"{d['padded_segments']} padded rows, "
          f"peak queue depth {d['max_pending']})")

    # every session must reproduce ITS OWN offline reconstruction exactly
    for sid, res in results.items():
        ref = run_emvs(cam, dsi_cfg,
                       aggregate(cam, feeds[sid], traj, EVENTS_PER_FRAME),
                       opts)
        assert [s.frame_range for s in res.segments] == \
            [s.frame_range for s in ref.segments], f"{sid}: boundaries"
        worst = max((float(np.abs(np.asarray(a.dsi, np.float32)
                                  - np.asarray(b.dsi, np.float32)).max())
                     for a, b in zip(res.segments, ref.segments)),
                    default=0.0)
        print(f"  [{sid}] offline equivalence over {len(res.segments)} "
              f"segments: max |DSI delta| = {worst:g}")
    print("OK: every session matches its dedicated offline reconstruction")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="simulation_3planes",
                    choices=["simulation_3planes", "simulation_3walls",
                             "slider_close", "slider_far"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--points", type=int, default=400)
    ap.add_argument("--planes", type=int, default=64)
    ap.add_argument("--chunk-frames", type=int, default=1,
                    help="push granularity, in aggregated frames")
    ap.add_argument("--sweep", default="batched",
                    choices=["batched", "sharded"],
                    help="segment-sweep backend (see StreamConfig.sweep)")
    ap.add_argument("--policy", default="adaptive",
                    choices=["latency", "throughput", "adaptive"],
                    help="dispatch policy for the closed-segment coalescing "
                         "queue: latency = sweep each segment immediately "
                         "(lowest first-depth latency), throughput = fill "
                         "the largest S bucket before dispatching (highest "
                         "sustained segments/s), adaptive = never wait while "
                         "the device keeps up (lone segments go solo, queued "
                         "backlogs coalesce), hold-to-coalesce when the "
                         "in-flight queue saturates (default)")
    ap.add_argument("--target-latency-ms", type=float, default=None,
                    help="SLO deadline for the adaptive planner: coalesce "
                         "while the cost model predicts the queue drains "
                         "inside this budget, dispatch eagerly otherwise; "
                         "prints predicted vs actual drain time per "
                         "dispatch (requires --policy adaptive)")
    ap.add_argument("--pose-lag", type=float, default=None,
                    help="stream poses too, lagging the event front by this "
                         "many seconds (default: fully-known pose oracle)")
    ap.add_argument("--max-stall", type=int, default=None,
                    help="pose-gated back-pressure: max frames stalled past "
                         "the pose watermark before push raises "
                         "PoseStallError; frames are buffered first, so "
                         "pushing the missing poses recovers "
                         "(default: unbounded)")
    ap.add_argument("--sessions", type=int, default=1,
                    help="N > 1 simulates an N-camera rig on one "
                         "MultiStreamEngine: per-session event streams "
                         "(different sensor noise), round-robin chunk "
                         "interleave, cross-stream coalescing on the shared "
                         "dispatcher (default: 1, single-stream engine)")
    ap.add_argument("--hygiene", default="raise", choices=HYGIENE_POLICIES,
                    help="ingest guard policy for adversarial chunks: raise "
                         "= typed errors (default), drop = shed offenders, "
                         "reorder = absorb misordering within "
                         "--reorder-slack, off = no guard")
    ap.add_argument("--reorder-slack", type=float, default=0.0,
                    help="reorder-buffer depth in seconds (hygiene=reorder): "
                         "events are held until the max observed time moves "
                         "this far past them")
    ap.add_argument("--hot-pixel-limit", type=int, default=None,
                    help="max events per pixel per 50 ms window before the "
                         "hot-pixel guard trips (default: unlimited)")
    ap.add_argument("--corrupt", default=None, choices=EVENT_CORRUPTIONS,
                    help="fault-inject one corruption mode into the stream "
                         "and demo the hygiene response")
    ap.add_argument("--budget-frames", type=int, default=None,
                    help="cap the frame store at this many frames' bytes; "
                         "admission stalls or rejects per --budget-policy "
                         "(default: unbounded)")
    ap.add_argument("--budget-policy", default="stall",
                    choices=["stall", "reject"],
                    help="over-budget admission: stall = back-pressure until "
                         "a queued segment drains, reject = raise "
                         "MemoryBudgetError (frames kept; poll() retries)")
    ap.add_argument("--out", default="/tmp/emvs_stream.npz")
    args = ap.parse_args()
    if args.sessions < 1:
        ap.error("--sessions must be >= 1")

    cam = CameraModel()
    scene = make_scene(SceneConfig(name=args.scene, points_per_plane=args.points))
    traj = make_trajectory(args.scene, args.steps)
    events = simulate_events(cam, scene, traj, noise_fraction=0.02)
    z = (0.5, 1.8) if args.scene == "slider_close" else (0.6, 4.5)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=args.planes,
                                   z_min=z[0], z_max=z[1])
    opts = EMVSOptions(voting="nearest", formulation="matmul", quantized=True)
    print(f"scene={args.scene}: {int(events.valid.sum())} events, "
          f"DSI {dsi_cfg.shape}, chunk={args.chunk_frames} frame(s)")

    pose_gated = args.pose_lag is not None
    if args.max_stall is not None and not pose_gated:
        ap.error("--max-stall requires --pose-lag: the stall bound only "
                 "applies to a streamed (pose-gated) trajectory")
    cost_model = None
    if args.target_latency_ms is not None:
        if args.policy != "adaptive":
            ap.error("--target-latency-ms drives the SLO-aware ADAPTIVE "
                     "planner; use --policy adaptive")
        if args.sessions > 1:
            ap.error("--target-latency-ms demos the single-stream SLO "
                     "planner; use --sessions 1")
        from repro.profiling import AffineCostModel, CostTable
        from repro.profiling.cost_model import model_from_table
        try:
            table = CostTable.load("cost_table.json")
            cost_model = model_from_table(table)
            print(f"SLO planner: deadline {args.target_latency_ms:g} ms, "
                  f"cost model from cost_table.json "
                  f"({len(table)} measured variants)")
        except FileNotFoundError:
            # rough prior: a few ms of dispatch overhead plus a per-row
            # rate; real numbers come from the recorded table
            cost_model = AffineCostModel(params={
                "batched": (5e-3, 2e-4), "sharded": (1e-2, 1e-4)})
            print(f"SLO planner: deadline {args.target_latency_ms:g} ms, "
                  f"built-in affine prior (no cost_table.json — run "
                  f"benchmarks/streaming_latency.py to record one)")
    if args.corrupt and pose_gated:
        ap.error("--corrupt demos the ingest guard on the plain event "
                 "stream; use it without --pose-lag")
    if args.sessions > 1:
        if pose_gated:
            ap.error("--pose-lag demos the pose-gated tracker model on a "
                     "single stream; use --sessions 1")
        if args.corrupt:
            ap.error("--corrupt demos the single-stream ingest guard; "
                     "use --sessions 1")
        run_multi(args, cam, scene, traj, dsi_cfg, opts)
        return
    engine = EMVSStreamEngine(cam, dsi_cfg, None if pose_gated else traj,
                              opts, StreamConfig(
                                  sweep=args.sweep,
                                  dispatch_policy=args.policy,
                                  target_latency_s=(
                                      args.target_latency_ms / 1e3
                                      if args.target_latency_ms is not None
                                      else None),
                                  max_stalled_frames=args.max_stall,
                                  hygiene=HygieneConfig(
                                      policy=args.hygiene,
                                      reorder_slack=args.reorder_slack,
                                      hot_pixel_limit=args.hot_pixel_limit),
                                  frame_store_budget_bytes=(
                                      frame_budget_bytes(args.budget_frames)
                                      if args.budget_frames else None),
                                  budget_policy=args.budget_policy),
                              cost_model=cost_model)
    t0 = time.time()

    # --target-latency-ms: per-dispatch predicted-vs-actual drain audit.
    # When a dispatch goes out, snapshot the model's drain prediction;
    # when the queue next goes fully idle, print it next to the wall
    # time the drain actually took.
    drain_watch: list = []  # [dispatch #, t_dispatched, predicted_s]
    drain_seen = 0

    def watch_drain() -> None:
        nonlocal drain_seen
        if args.target_latency_ms is None:
            return
        now = time.time() - t0
        n = engine.stats["dispatches"]
        if n > drain_seen:
            pred = engine.predict_drain_s()
            for k in range(drain_seen + 1, n + 1):
                drain_watch.append([k, now, pred])
            drain_seen = n
        if drain_watch and not engine._inflight \
                and engine.stats["pending_segments"] == 0:
            for k, t_disp, pred in drain_watch:
                print(f"  dispatch #{k}: predicted drain "
                      f"{pred * 1e3:7.1f} ms, actual "
                      f"{(now - t_disp) * 1e3:7.1f} ms")
            drain_watch.clear()

    def report(seg, when):
        gt, gtm = ground_truth_depth(cam, scene, seg.T_w_ref)
        err = float(absrel(seg.depth_map.depth, seg.depth_map.mask, gt, gtm))
        px = int(np.asarray(seg.depth_map.mask).sum())
        print(f"  t={when:6.1f}s  keyframe {seg.frame_range}: "
              f"AbsRel {err:.4f}  {px:6d} px")

    pose_times = np.asarray(traj.times)
    pose_sent = 0  # pose samples already pushed (pose-gated mode)

    def push_poses_behind(event_front: float) -> list:
        """Tracker model: poses are available up to event_front - lag."""
        nonlocal pose_sent
        hi = int(np.searchsorted(pose_times, event_front - args.pose_lag,
                                 side="right"))
        if hi <= pose_sent:
            return []
        lo, pose_sent = pose_sent, hi
        return engine.push_poses(slice_trajectory(traj, lo, hi))

    chunk_events = args.chunk_frames * EVENTS_PER_FRAME
    if args.corrupt:
        chunks = corrupt_stream(events, args.corrupt, chunk_events, seed=0,
                                width=cam.width, height=cam.height)
        print(f"fault injection: {args.corrupt} (mid-stream), "
              f"hygiene={args.hygiene}")
    else:
        chunks = iter_event_chunks(events, chunk_events)

    def guarded_push(chunk):
        """push with the reject-policy recovery loop: on MemoryBudgetError
        the frames are retained in the backlog; poll() retries admission."""
        try:
            return engine.push(chunk)
        except MemoryBudgetError:
            if args.budget_policy != "reject":
                raise
            print(f"  budget reject (backlog "
                  f"{engine.stats['backlog_frames']} frame(s)); retrying "
                  f"via poll()")
            for _ in range(1000):
                segs = engine.poll()
                if not engine.stats["backlog_frames"]:
                    return segs
            raise

    print("streaming..." + (f" (pose stream lagging {args.pose_lag}s)"
                            if pose_gated else ""))
    try:
        for chunk in chunks:
            for seg in guarded_push(chunk):
                report(seg, time.time() - t0)
            if pose_gated:
                for seg in push_poses_behind(float(np.asarray(chunk.t)[-1])):
                    report(seg, time.time() - t0)
            watch_drain()
    except StreamHygieneError as e:
        print(f"stream REJECTED by hygiene={args.hygiene!r}: "
              f"{type(e).__name__}: {e}")
        print("(policies 'drop'/'reorder' shed or absorb instead; "
              "this is the fail-loud default)")
        return
    if pose_gated:
        # tracker drains: deliver the remaining poses, then close the stream
        # (segments completed by the drain burst are reported here, not lost)
        for seg in push_poses_behind(float("inf")):
            report(seg, time.time() - t0)
        for seg in engine.finalize_poses():
            report(seg, time.time() - t0)
        print(f"pose stream done: watermark t="
              f"{engine.stats['pose_watermark']:.3f}, "
              f"max stall {engine.stats['max_stalled']} frame(s)")
    print("end of stream -> flush")
    known = {s.frame_range for s in engine.result().segments}
    res = engine.flush()
    for seg in res.segments:
        if seg.frame_range not in known:
            report(seg, time.time() - t0)
    watch_drain()  # flush drained the queue: settle the open predictions
    if args.target_latency_ms is not None:
        print(f"SLO deadline {args.target_latency_ms:g} ms: "
              f"{engine.stats['slo_dispatches']} deadline-driven "
              f"dispatch(es), {engine.stats['slo_holds']} hold(s) "
              f"with predicted slack")
    print(f"streamed {engine.stats['frames']} frames, "
          f"{engine.stats['dispatches']} dispatches "
          f"({engine.stats['padded_segments']} padded segment rows); "
          f"policy={args.policy}: {engine.stats['coalesced_segments']} "
          f"segment(s) coalesced into "
          f"{engine.stats['coalesced_dispatches']} batched dispatch(es), "
          f"peak queue depth {engine.stats['max_pending']}")
    h = engine.stats["hygiene"]
    if args.hygiene != "off":
        shed = (h["dropped_out_of_order"] + h["dropped_duplicate_events"]
                + h["dropped_out_of_bounds"] + h["dropped_hot_pixel"])
        print(f"hygiene={args.hygiene}: {h['events_in']} events in, "
              f"{shed} shed, peak reorder hold "
              f"{h['reorder_peak_held']} event(s)")
    if args.budget_frames:
        print(f"budget={args.budget_frames} frame(s): peak frame store "
              f"{engine.stats['frame_store_peak_bytes']} / "
              f"{frame_budget_bytes(args.budget_frames)} bytes, "
              f"{engine.stats['budget_stalls']} stall(s), "
              f"{engine.stats['budget_rejects']} reject(s)")

    # the streamed reconstruction is the offline one, segment for segment —
    # unless the stream was corrupted and the policy sheds (drop) or
    # ignores (off) the faults rather than absorbing them bitwise (reorder)
    if args.corrupt and args.hygiene != "reorder":
        print(f"offline equivalence skipped: the {args.corrupt} stream "
              + ("was shed down to a clean subset"
                 if args.hygiene == "drop" else
                 "went in UNGUARDED — results are not trustworthy"))
    else:
        ref = run_emvs(cam, dsi_cfg,
                       aggregate(cam, events, traj, EVENTS_PER_FRAME), opts)
        assert [s.frame_range for s in res.segments] == \
            [s.frame_range for s in ref.segments]
        worst = max((float(np.abs(np.asarray(a.dsi, np.float32)
                                  - np.asarray(b.dsi, np.float32)).max())
                     for a, b in zip(res.segments, ref.segments)),
                    default=0.0)
        print(f"offline equivalence: max |DSI_stream - DSI_offline| = "
              f"{worst:g}")

    cloud = concatenate(res.clouds)
    cloud = radius_outlier_filter(cloud, radius=0.08, min_neighbors=2)
    n = int(np.asarray(cloud.valid).sum())
    print(f"merged global map: {n} points after outlier filtering")
    np.savez(
        args.out,
        points=np.asarray(cloud.points)[np.asarray(cloud.valid)],
        weights=np.asarray(cloud.weights)[np.asarray(cloud.valid)],
        depth_last=np.asarray(res.segments[-1].depth_map.depth),
        mask_last=np.asarray(res.segments[-1].depth_map.mask),
    )
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
