"""Streaming EMVS demo: depth maps while the sensor is still moving.

The offline demo (`emvs_reconstruction.py`) aggregates the whole
recording, then reconstructs. This variant feeds the same event stream
chunk-by-chunk into `EMVSStreamEngine`: key-frame segments close the
moment the K criterion trips, vote on the device while later events are
still arriving (double-buffered dispatch), and depth maps are printed as
they complete. The final result is bit-identical to `run_emvs` on the
default nearest/integer datapath.

    PYTHONPATH=src python examples/emvs_streaming.py \
        [--scene simulation_3walls] [--chunk-frames 2] [--sweep sharded] \
        [--out /tmp/emvs_stream.npz]

`--sweep sharded` dispatches each closed-segment bucket through
`repro.distributed.emvs.process_segments_sharded` (segment axis sharded
over all local devices) instead of the serial `lax.map` sweep; results
stay bit-identical on the default nearest/integer datapath.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import EMVSOptions, run_emvs
from repro.core.pointcloud import concatenate, radius_outlier_filter
from repro.events.aggregation import EVENTS_PER_FRAME, aggregate
from repro.events.simulator import (
    SceneConfig, absrel, ground_truth_depth, make_scene, make_trajectory,
    simulate_events,
)
from repro.serving.emvs_stream import (
    EMVSStreamEngine, StreamConfig, iter_event_chunks,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="simulation_3planes",
                    choices=["simulation_3planes", "simulation_3walls",
                             "slider_close", "slider_far"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--points", type=int, default=400)
    ap.add_argument("--planes", type=int, default=64)
    ap.add_argument("--chunk-frames", type=int, default=1,
                    help="push granularity, in aggregated frames")
    ap.add_argument("--sweep", default="batched",
                    choices=["batched", "sharded"],
                    help="segment-sweep backend (see StreamConfig.sweep)")
    ap.add_argument("--out", default="/tmp/emvs_stream.npz")
    args = ap.parse_args()

    cam = CameraModel()
    scene = make_scene(SceneConfig(name=args.scene, points_per_plane=args.points))
    traj = make_trajectory(args.scene, args.steps)
    events = simulate_events(cam, scene, traj, noise_fraction=0.02)
    z = (0.5, 1.8) if args.scene == "slider_close" else (0.6, 4.5)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=args.planes,
                                   z_min=z[0], z_max=z[1])
    opts = EMVSOptions(voting="nearest", formulation="matmul", quantized=True)
    print(f"scene={args.scene}: {int(events.valid.sum())} events, "
          f"DSI {dsi_cfg.shape}, chunk={args.chunk_frames} frame(s)")

    engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts,
                              StreamConfig(sweep=args.sweep))
    t0 = time.time()

    def report(seg, when):
        gt, gtm = ground_truth_depth(cam, scene, seg.T_w_ref)
        err = float(absrel(seg.depth_map.depth, seg.depth_map.mask, gt, gtm))
        px = int(np.asarray(seg.depth_map.mask).sum())
        print(f"  t={when:6.1f}s  keyframe {seg.frame_range}: "
              f"AbsRel {err:.4f}  {px:6d} px")

    print("streaming...")
    for chunk in iter_event_chunks(events, args.chunk_frames * EVENTS_PER_FRAME):
        for seg in engine.push(chunk):
            report(seg, time.time() - t0)
    print("end of stream -> flush")
    known = {s.frame_range for s in engine.result().segments}
    res = engine.flush()
    for seg in res.segments:
        if seg.frame_range not in known:
            report(seg, time.time() - t0)
    print(f"streamed {engine.stats['frames']} frames, "
          f"{engine.stats['dispatches']} dispatches "
          f"({engine.stats['padded_segments']} padded segment rows)")

    # the streamed reconstruction is the offline one, segment for segment
    ref = run_emvs(cam, dsi_cfg,
                   aggregate(cam, events, traj, EVENTS_PER_FRAME), opts)
    assert [s.frame_range for s in res.segments] == \
        [s.frame_range for s in ref.segments]
    worst = max((float(np.abs(np.asarray(a.dsi, np.float32)
                              - np.asarray(b.dsi, np.float32)).max())
                 for a, b in zip(res.segments, ref.segments)), default=0.0)
    print(f"offline equivalence: max |DSI_stream - DSI_offline| = {worst:g}")

    cloud = concatenate(res.clouds)
    cloud = radius_outlier_filter(cloud, radius=0.08, min_neighbors=2)
    n = int(np.asarray(cloud.valid).sum())
    print(f"merged global map: {n} points after outlier filtering")
    np.savez(
        args.out,
        points=np.asarray(cloud.points)[np.asarray(cloud.valid)],
        weights=np.asarray(cloud.weights)[np.asarray(cloud.valid)],
        depth_last=np.asarray(res.segments[-1].depth_map.depth),
        mask_last=np.asarray(res.segments[-1].depth_map.mask),
    )
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
