"""Streaming EMVS demo: depth maps while the sensor is still moving.

The offline demo (`emvs_reconstruction.py`) aggregates the whole
recording, then reconstructs. This variant feeds the same event stream
chunk-by-chunk into `EMVSStreamEngine`: key-frame segments close the
moment the K criterion trips, vote on the device while later events are
still arriving (double-buffered dispatch), and depth maps are printed as
they complete. The final result is bit-identical to `run_emvs` on the
default nearest/integer datapath.

    PYTHONPATH=src python examples/emvs_streaming.py \
        [--scene simulation_3walls] [--chunk-frames 2] [--sweep sharded] \
        [--policy adaptive] [--pose-lag 0.1] [--max-stall 32] \
        [--sessions 3] [--out /tmp/emvs_stream.npz]

`--sessions N` (N > 1) simulates an N-camera event rig: each session
gets its own event stream (same scene and trajectory, different sensor
noise), all multiplexed onto ONE `MultiStreamEngine` whose shared
dispatcher coalesces shape-compatible segments from different cameras
into the same device sweep (watch `cross_stream_dispatches` in the
summary). Chunks interleave round-robin across sessions; every
session's reconstruction is verified bit-identical to its own offline
`run_emvs`. The pose-gated flags (`--pose-lag`, `--max-stall`) demo
the single-stream tracker model and require `--sessions 1`.

`--sweep sharded` dispatches each closed-segment bucket through
`repro.distributed.emvs.process_segments_sharded` (segment axis sharded
over all local devices) instead of the serial `lax.map` sweep; results
stay bit-identical on the default nearest/integer datapath.

`--policy` picks how closed segments leave the coalescing queue:
"latency" sweeps every segment the moment it closes (lowest
time-to-depth-map), "throughput" holds segments until the largest S
bucket fills (fewest dispatches, biggest batches — pair with `--sweep
sharded` for cross-device parallelism), "adaptive" (default) never
waits while the device keeps up — a lone closed segment dispatches
solo, an already-queued backlog coalesces — and holds segments to
coalesce once the in-flight queue saturates. The reconstruction is
bit-identical under every policy — only the dispatch schedule moves.

`--max-stall N` (pose-gated mode) bounds the pose-stall queue: if the
tracker falls more than N frames behind the event front, `push` raises
`PoseStallError` instead of buffering unboundedly (the frames are kept;
pushing the missing pose chunks recovers).

`--pose-lag SECONDS` switches the pose source from the fully-known
`Trajectory` oracle to the streamed mode: pose chunks are pushed via
`engine.push_poses` lagging the event front by the given delay (a
tracker running behind the sensor), frames past the pose-lag watermark
stall until their bracketing poses arrive, and `finalize_poses` closes
the pose stream before the flush. The reconstruction stays bit-identical
to the oracle mode — only the latency profile changes.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import EMVSOptions, run_emvs
from repro.core.pointcloud import concatenate, radius_outlier_filter
from repro.events.aggregation import EVENTS_PER_FRAME, aggregate
from repro.events.simulator import (
    SceneConfig, absrel, ground_truth_depth, make_scene, make_trajectory,
    simulate_events, slice_trajectory,
)
from repro.serving.emvs_stream import (
    EMVSStreamEngine, MultiStreamEngine, StreamConfig, iter_event_chunks,
)


def run_multi(args, cam, scene, traj, dsi_cfg, opts) -> None:
    """N-camera rig demo: one shared dispatcher, round-robin interleave,
    per-session offline equivalence check, cross-stream coalescing
    summary."""
    engine = MultiStreamEngine(cam, dsi_cfg, opts,
                               StreamConfig(sweep=args.sweep,
                                            dispatch_policy=args.policy))
    feeds = {}
    for i in range(args.sessions):
        ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=i)
        sess = engine.add_session(f"cam{i}", traj=traj)
        feeds[sess.session_id] = ev
    chunks = {sid: iter_event_chunks(ev, args.chunk_frames * EVENTS_PER_FRAME)
              for sid, ev in feeds.items()}
    print(f"streaming {args.sessions} sessions, round-robin chunks of "
          f"{args.chunk_frames} frame(s)...")
    t0 = time.time()
    while chunks:
        drained = []
        for sid, it in chunks.items():
            chunk = next(it, None)
            if chunk is None:
                drained.append(sid)
                continue
            for seg in engine.push(sid, chunk):
                print(f"  t={time.time() - t0:6.1f}s  [{sid}] "
                      f"keyframe {seg.frame_range}")
        for sid in drained:
            del chunks[sid]
    print("end of all streams -> flush")
    results = engine.flush()
    d = engine.stats["dispatcher"]
    print(f"shared dispatcher: {d['segments']} segments in "
          f"{d['dispatches']} dispatches "
          f"({d['cross_stream_dispatches']} spanning multiple sessions, "
          f"{d['coalesced_segments']} segment(s) coalesced, "
          f"{d['padded_segments']} padded rows, "
          f"peak queue depth {d['max_pending']})")

    # every session must reproduce ITS OWN offline reconstruction exactly
    for sid, res in results.items():
        ref = run_emvs(cam, dsi_cfg,
                       aggregate(cam, feeds[sid], traj, EVENTS_PER_FRAME),
                       opts)
        assert [s.frame_range for s in res.segments] == \
            [s.frame_range for s in ref.segments], f"{sid}: boundaries"
        worst = max((float(np.abs(np.asarray(a.dsi, np.float32)
                                  - np.asarray(b.dsi, np.float32)).max())
                     for a, b in zip(res.segments, ref.segments)),
                    default=0.0)
        print(f"  [{sid}] offline equivalence over {len(res.segments)} "
              f"segments: max |DSI delta| = {worst:g}")
    print("OK: every session matches its dedicated offline reconstruction")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="simulation_3planes",
                    choices=["simulation_3planes", "simulation_3walls",
                             "slider_close", "slider_far"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--points", type=int, default=400)
    ap.add_argument("--planes", type=int, default=64)
    ap.add_argument("--chunk-frames", type=int, default=1,
                    help="push granularity, in aggregated frames")
    ap.add_argument("--sweep", default="batched",
                    choices=["batched", "sharded"],
                    help="segment-sweep backend (see StreamConfig.sweep)")
    ap.add_argument("--policy", default="adaptive",
                    choices=["latency", "throughput", "adaptive"],
                    help="dispatch policy for the closed-segment coalescing "
                         "queue: latency = sweep each segment immediately "
                         "(lowest first-depth latency), throughput = fill "
                         "the largest S bucket before dispatching (highest "
                         "sustained segments/s), adaptive = never wait while "
                         "the device keeps up (lone segments go solo, queued "
                         "backlogs coalesce), hold-to-coalesce when the "
                         "in-flight queue saturates (default)")
    ap.add_argument("--pose-lag", type=float, default=None,
                    help="stream poses too, lagging the event front by this "
                         "many seconds (default: fully-known pose oracle)")
    ap.add_argument("--max-stall", type=int, default=None,
                    help="pose-gated back-pressure: max frames stalled past "
                         "the pose watermark before push raises "
                         "PoseStallError; frames are buffered first, so "
                         "pushing the missing poses recovers "
                         "(default: unbounded)")
    ap.add_argument("--sessions", type=int, default=1,
                    help="N > 1 simulates an N-camera rig on one "
                         "MultiStreamEngine: per-session event streams "
                         "(different sensor noise), round-robin chunk "
                         "interleave, cross-stream coalescing on the shared "
                         "dispatcher (default: 1, single-stream engine)")
    ap.add_argument("--out", default="/tmp/emvs_stream.npz")
    args = ap.parse_args()
    if args.sessions < 1:
        ap.error("--sessions must be >= 1")

    cam = CameraModel()
    scene = make_scene(SceneConfig(name=args.scene, points_per_plane=args.points))
    traj = make_trajectory(args.scene, args.steps)
    events = simulate_events(cam, scene, traj, noise_fraction=0.02)
    z = (0.5, 1.8) if args.scene == "slider_close" else (0.6, 4.5)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=args.planes,
                                   z_min=z[0], z_max=z[1])
    opts = EMVSOptions(voting="nearest", formulation="matmul", quantized=True)
    print(f"scene={args.scene}: {int(events.valid.sum())} events, "
          f"DSI {dsi_cfg.shape}, chunk={args.chunk_frames} frame(s)")

    pose_gated = args.pose_lag is not None
    if args.max_stall is not None and not pose_gated:
        ap.error("--max-stall requires --pose-lag: the stall bound only "
                 "applies to a streamed (pose-gated) trajectory")
    if args.sessions > 1:
        if pose_gated:
            ap.error("--pose-lag demos the pose-gated tracker model on a "
                     "single stream; use --sessions 1")
        run_multi(args, cam, scene, traj, dsi_cfg, opts)
        return
    engine = EMVSStreamEngine(cam, dsi_cfg, None if pose_gated else traj,
                              opts, StreamConfig(
                                  sweep=args.sweep,
                                  dispatch_policy=args.policy,
                                  max_stalled_frames=args.max_stall))
    t0 = time.time()

    def report(seg, when):
        gt, gtm = ground_truth_depth(cam, scene, seg.T_w_ref)
        err = float(absrel(seg.depth_map.depth, seg.depth_map.mask, gt, gtm))
        px = int(np.asarray(seg.depth_map.mask).sum())
        print(f"  t={when:6.1f}s  keyframe {seg.frame_range}: "
              f"AbsRel {err:.4f}  {px:6d} px")

    pose_times = np.asarray(traj.times)
    pose_sent = 0  # pose samples already pushed (pose-gated mode)

    def push_poses_behind(event_front: float) -> list:
        """Tracker model: poses are available up to event_front - lag."""
        nonlocal pose_sent
        hi = int(np.searchsorted(pose_times, event_front - args.pose_lag,
                                 side="right"))
        if hi <= pose_sent:
            return []
        lo, pose_sent = pose_sent, hi
        return engine.push_poses(slice_trajectory(traj, lo, hi))

    print("streaming..." + (f" (pose stream lagging {args.pose_lag}s)"
                            if pose_gated else ""))
    for chunk in iter_event_chunks(events, args.chunk_frames * EVENTS_PER_FRAME):
        for seg in engine.push(chunk):
            report(seg, time.time() - t0)
        if pose_gated:
            for seg in push_poses_behind(float(np.asarray(chunk.t)[-1])):
                report(seg, time.time() - t0)
    if pose_gated:
        # tracker drains: deliver the remaining poses, then close the stream
        # (segments completed by the drain burst are reported here, not lost)
        for seg in push_poses_behind(float("inf")):
            report(seg, time.time() - t0)
        for seg in engine.finalize_poses():
            report(seg, time.time() - t0)
        print(f"pose stream done: watermark t="
              f"{engine.stats['pose_watermark']:.3f}, "
              f"max stall {engine.stats['max_stalled']} frame(s)")
    print("end of stream -> flush")
    known = {s.frame_range for s in engine.result().segments}
    res = engine.flush()
    for seg in res.segments:
        if seg.frame_range not in known:
            report(seg, time.time() - t0)
    print(f"streamed {engine.stats['frames']} frames, "
          f"{engine.stats['dispatches']} dispatches "
          f"({engine.stats['padded_segments']} padded segment rows); "
          f"policy={args.policy}: {engine.stats['coalesced_segments']} "
          f"segment(s) coalesced into "
          f"{engine.stats['coalesced_dispatches']} batched dispatch(es), "
          f"peak queue depth {engine.stats['max_pending']}")

    # the streamed reconstruction is the offline one, segment for segment
    ref = run_emvs(cam, dsi_cfg,
                   aggregate(cam, events, traj, EVENTS_PER_FRAME), opts)
    assert [s.frame_range for s in res.segments] == \
        [s.frame_range for s in ref.segments]
    worst = max((float(np.abs(np.asarray(a.dsi, np.float32)
                              - np.asarray(b.dsi, np.float32)).max())
                 for a, b in zip(res.segments, ref.segments)), default=0.0)
    print(f"offline equivalence: max |DSI_stream - DSI_offline| = {worst:g}")

    cloud = concatenate(res.clouds)
    cloud = radius_outlier_filter(cloud, radius=0.08, min_neighbors=2)
    n = int(np.asarray(cloud.valid).sum())
    print(f"merged global map: {n} points after outlier filtering")
    np.savez(
        args.out,
        points=np.asarray(cloud.points)[np.asarray(cloud.valid)],
        weights=np.asarray(cloud.weights)[np.asarray(cloud.valid)],
        depth_last=np.asarray(res.segments[-1].depth_map.depth),
        mask_last=np.asarray(res.segments[-1].depth_map.mask),
    )
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
