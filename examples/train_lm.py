"""End-to-end LM training driver: a ~100M-parameter qwen3-family model
trained for a few hundred steps with the full production substrate
(AdamW+cosine, remat, microbatching, rolling checkpoints, preemption
drain, straggler watchdog, deterministic restartable data).

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # smoke (~1 min)

This is the same code path the 512-chip dry-run compiles — only the mesh
differs (here: the host device).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.fault_tolerance import PreemptionHandler, StragglerMonitor
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import TrainOptions, init_train_state, make_train_step

# ~100M params: 12 x d512 GQA blocks + 32k vocab (qwen3 family: qk-norm)
LM100M = ArchConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2048, vocab_size=32768, d_head=64, qk_norm=True,
    source="example config (~100M params)")

TINY = ArchConfig(
    name="lm-tiny", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=2048, d_head=32,
    source="example smoke config")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = TINY if args.tiny else LM100M
    if args.tiny:
        args.steps, args.seq, args.batch = min(args.steps, 30), 64, 4

    opts = TrainOptions(
        microbatches=args.microbatches, remat=True,
        opt=AdamWConfig(peak_lr=6e-4, warmup_steps=max(args.steps // 10, 10),
                        total_steps=args.steps))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opts)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    start = 0
    last = ckpt.latest(args.ckpt_dir)
    if last is not None and last < args.steps:
        state = ckpt.restore(args.ckpt_dir, last, state)
        start = last
        print(f"[restore] resumed from step {last}")

    step_fn = jax.jit(make_train_step(cfg, opts), donate_argnums=(0,))
    data = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch))
    drain, watchdog = PreemptionHandler(), StragglerMonitor()
    t_start, tokens_seen = time.time(), 0
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        metrics = jax.tree.map(float, metrics)
        dt = time.time() - t0
        tokens_seen += args.batch * args.seq
        if (step + 1) % 10 == 0 or step == start:
            print(f"step {step + 1:4d}  loss {metrics['loss']:.4f}  "
                  f"lr {metrics['lr']:.2e}  gnorm {metrics['grad_norm']:.2f}  "
                  f"{args.batch * args.seq / dt:,.0f} tok/s", flush=True)
        if watchdog.observe(dt) == "drain":
            print("[straggler] persistent slow steps: checkpoint + drain")
            ckpt.save(args.ckpt_dir, step + 1, state)
            return
        if (step + 1) % args.ckpt_every == 0 or drain.should_drain:
            ckpt.save(args.ckpt_dir, step + 1, state)
            if drain.should_drain:
                print("[drain] preempted; exiting cleanly")
                return
    ckpt.save(args.ckpt_dir, args.steps, state)
    dt = time.time() - t_start
    print(f"done: {tokens_seen:,} tokens in {dt:.0f}s "
          f"({tokens_seen / dt:,.0f} tok/s end-to-end)")


if __name__ == "__main__":
    main()
