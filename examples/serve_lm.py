"""Serving example: continuous batching with int8 KV-cache quantization.

Compares bf16 vs int8 KV caches on identical traffic — the LM
instantiation of the paper's Table-1 memory-halving insight.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-8b]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models.kv_cache import cache_bytes
from repro.serving.engine import Engine, EngineConfig, Request


def drive(cfg, params, *, int8: bool, n_requests: int, seed: int = 0):
    eng = Engine(cfg, params,
                 EngineConfig(slots=4, max_len=192, kv_quantized=int8,
                              prefill_buckets=(32, 64)),
                 eos_id=-1)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        p = rng.integers(1, cfg.vocab_size, int(rng.integers(8, 32)))
        r = Request(rid=i, prompt=p.astype(np.int32), max_new_tokens=24)
        reqs.append(r)
        eng.submit(r)
    t0 = time.time()
    eng.run_until_done(100000)
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in reqs)
    kv_bytes = sum(cache_bytes(s) for s in eng.state
                   if hasattr(s, "k"))
    return reqs, toks / dt, kv_bytes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    r16, tps16, b16 = drive(cfg, params, int8=False, n_requests=args.requests)
    r8, tps8, b8 = drive(cfg, params, int8=True, n_requests=args.requests)

    agree = np.mean([
        np.mean([a == b for a, b in zip(x.generated, y.generated)])
        for x, y in zip(r16, r8)])
    print(f"bf16 KV: {tps16:8.1f} tok/s  cache {b16 / 2 ** 20:6.1f} MiB")
    print(f"int8 KV: {tps8:8.1f} tok/s  cache {b8 / 2 ** 20:6.1f} MiB "
          f"({b16 / max(b8, 1):.2f}x smaller)")
    print(f"greedy-token agreement bf16 vs int8: {agree * 100:.1f}%")


if __name__ == "__main__":
    main()
