"""Full EMVS reconstruction demo: every pipeline stage, all datapaths.

Walks A -> P -> R -> K -> D -> M on a synthetic sequence, compares the
three voting formulations and the quantized datapath, and writes the
reconstruction (depth maps + merged point cloud) to an .npz.

    PYTHONPATH=src python examples/emvs_reconstruction.py \
        [--scene simulation_3walls] [--out /tmp/emvs_recon.npz]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import EMVSOptions, run_emvs
from repro.core.pointcloud import concatenate, radius_outlier_filter
from repro.events.aggregation import aggregate
from repro.events.simulator import (
    SceneConfig, absrel, ground_truth_depth, make_scene, make_trajectory,
    simulate_events,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="simulation_3planes",
                    choices=["simulation_3planes", "simulation_3walls",
                             "slider_close", "slider_far"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--points", type=int, default=400)
    ap.add_argument("--planes", type=int, default=64)
    ap.add_argument("--out", default="/tmp/emvs_recon.npz")
    args = ap.parse_args()

    cam = CameraModel()
    scene = make_scene(SceneConfig(name=args.scene, points_per_plane=args.points))
    traj = make_trajectory(args.scene, args.steps)
    events = simulate_events(cam, scene, traj, noise_fraction=0.02)
    frames = aggregate(cam, events, traj)
    z = (0.5, 1.8) if args.scene == "slider_close" else (0.6, 4.5)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=args.planes,
                                   z_min=z[0], z_max=z[1])
    print(f"scene={args.scene}: {int(events.valid.sum())} events, "
          f"{frames.xy.shape[0]} frames, DSI {dsi_cfg.shape}")

    variants = {
        "scatter/float (original EMVS)": EMVSOptions(
            voting="bilinear", formulation="scatter"),
        "matmul/nearest (Eventor reformulation)": EMVSOptions(
            voting="nearest", formulation="matmul"),
        "matmul/nearest + Table-1 quantization": EMVSOptions(
            voting="nearest", formulation="matmul", quantized=True),
        "Pallas kernel (interpret) + quantization": EMVSOptions(
            voting="nearest", formulation="kernel", quantized=True),
    }
    results = {}
    for name, opts in variants.items():
        t0 = time.time()
        res = run_emvs(cam, dsi_cfg, frames, opts)
        dt = time.time() - t0
        errs, px = [], 0
        for seg in res.segments:
            gt, gtm = ground_truth_depth(cam, scene, seg.T_w_ref)
            errs.append(float(absrel(seg.depth_map.depth, seg.depth_map.mask,
                                     gt, gtm)))
            px += int(seg.depth_map.mask.sum())
        results[name] = res
        print(f"{name:44s} AbsRel {np.mean(errs):.4f}  "
              f"{px:6d} px  {dt:6.1f}s  ({len(res.segments)} keyframes)")

    # merge + filter the map of the reformulated variant (stage M)
    res = results["matmul/nearest + Table-1 quantization"]
    cloud = concatenate(res.clouds)
    cloud = radius_outlier_filter(cloud, radius=0.08, min_neighbors=2)
    n = int(np.asarray(cloud.valid).sum())
    print(f"merged global map: {n} points after outlier filtering")

    np.savez(
        args.out,
        points=np.asarray(cloud.points)[np.asarray(cloud.valid)],
        weights=np.asarray(cloud.weights)[np.asarray(cloud.valid)],
        depth0=np.asarray(res.segments[0].depth_map.depth),
        mask0=np.asarray(res.segments[0].depth_map.mask),
    )
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
